//! The acquisition and refit escape hatches, exercised through the real
//! process environment: `TRIMTUNER_ALPHA=clone` (per-candidate
//! clone-conditioning), `TRIMTUNER_TREES=rebuild` (per-candidate seeded
//! tree rebuilds) and `TRIMTUNER_REFIT=full` (from-scratch recomputation
//! of the incrementally maintained surrogate state every round).
//!
//! Environment mutation is process-global, so everything lives in ONE test
//! function of its own integration binary — the parallel test threads of
//! `alpha_parity` / the unit suites never see these variables.

use trimtuner::acq::{
    trimtuner_alpha, AlphaMode, AlphaSlate, EntropyEstimator, Models,
    TrimTunerAcq,
};
use trimtuner::engine::{RefitMode, RefitPolicy};
use trimtuner::models::{
    ExtraTrees, FantasySurface, Feat, FitOptions, ModelKind, Surrogate,
    TreesMode, TreesOptions,
};
use trimtuner::sim::{CloudSim, NetKind};
use trimtuner::space::{encode, Config, Constraint, Point};
use trimtuner::util::Rng;

fn observations(n: usize, seed: u64) -> (Vec<Feat>, Vec<f64>) {
    let sim = CloudSim::new(NetKind::Mlp);
    let mut rng = Rng::new(seed);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for _ in 0..n {
        let p = Point {
            config: Config::from_id(rng.below(288)),
            s_idx: rng.below(5),
        };
        let o = sim.observe(&p, &mut rng);
        xs.push(encode(&p));
        ys.push(o.acc);
    }
    (xs, ys)
}

#[test]
fn env_hatches_select_the_reference_paths() {
    // default environment: all hatches off
    std::env::remove_var("TRIMTUNER_ALPHA");
    std::env::remove_var("TRIMTUNER_TREES");
    std::env::remove_var("TRIMTUNER_REFIT");
    assert_eq!(AlphaMode::from_env(), AlphaMode::Fantasy);
    assert_eq!(TreesMode::from_env(), TreesMode::Incremental);
    assert_eq!(RefitMode::from_env(), RefitMode::Incremental);

    // --- TRIMTUNER_REFIT=full: from-scratch refit reference ------------
    // (the mode is pure plumbing — `EngineConfig::refit.mode` carries it
    // into the engine, and `tests/refit_parity.rs` pins the two paths
    // against each other — so the env side only needs the mapping)
    std::env::set_var("TRIMTUNER_REFIT", "full");
    assert_eq!(RefitMode::from_env(), RefitMode::Full);
    std::env::set_var("TRIMTUNER_REFIT", "FULL");
    assert_eq!(RefitMode::from_env(), RefitMode::Full);
    std::env::set_var("TRIMTUNER_REFIT", "incremental");
    assert_eq!(RefitMode::from_env(), RefitMode::Incremental);
    std::env::set_var("TRIMTUNER_REFIT", "full");
    assert_eq!(
        RefitPolicy::paper_default().mode,
        RefitMode::Full,
        "paper_default must pick the ambient refit mode up"
    );
    std::env::remove_var("TRIMTUNER_REFIT");
    assert_eq!(RefitPolicy::paper_default().mode, RefitMode::Incremental);

    // --- TRIMTUNER_TREES=rebuild: the per-candidate seeded rebuild -----
    let (xs, ys) = observations(22, 7);
    let mut et = ExtraTrees::new(TreesOptions::default());
    et.fit(&xs, &ys, FitOptions::default());
    let grid: Vec<Feat> = (0..288)
        .step_by(24)
        .map(|id| encode(&Point { config: Config::from_id(id), s_idx: 4 }))
        .collect();
    let x = encode(&Point { config: Config::from_id(33), s_idx: 1 });
    let default_view = et.fantasy_surface(&grid, 4).view(&x);

    std::env::set_var("TRIMTUNER_TREES", "rebuild");
    assert_eq!(TreesMode::from_env(), TreesMode::Rebuild);
    let rebuild_view = et.fantasy_surface(&grid, 4).view(&x);
    std::env::remove_var("TRIMTUNER_TREES");

    for ((am, astd), (bm, bstd)) in
        default_view.grid.iter().zip(&rebuild_view.grid)
    {
        assert_eq!(am.to_bits(), bm.to_bits(), "rebuild hatch diverged");
        assert_eq!(astd.to_bits(), bstd.to_bits(), "rebuild hatch diverged");
    }

    // --- TRIMTUNER_ALPHA=clone: per-candidate clone-conditioning -------
    let mut rng = Rng::new(11);
    let mut pts = Vec::new();
    let mut outs = Vec::new();
    let sim = CloudSim::new(NetKind::Mlp);
    for _ in 0..20 {
        let p = Point {
            config: Config::from_id(rng.below(288)),
            s_idx: rng.below(5),
        };
        pts.push(p);
        outs.push(sim.observe(&p, &mut rng));
    }
    let mut models = Models::new(ModelKind::Trees, 3);
    models.fit(&pts, &outs, FitOptions { hyperopt: true, restarts: 1 });
    let full_feats: Vec<Feat> = (0..288)
        .map(|id| encode(&Point { config: Config::from_id(id), s_idx: 4 }))
        .collect();
    let rep: Vec<Feat> = (0..10).map(|i| full_feats[i * 28]).collect();
    let est = EntropyEstimator::new(rep, 40, &mut rng);
    let baseline =
        EntropyEstimator::kl_from_uniform(&est.p_opt(models.acc.as_ref()));
    let shortlist: Vec<usize> = (0..288).step_by(18).collect();
    let shortlist_feats: Vec<Feat> =
        shortlist.iter().map(|&id| full_feats[id]).collect();
    let constraints = vec![Constraint::cost_max(0.06)];
    let ctx = TrimTunerAcq {
        models: &models,
        est: &est,
        constraints: &constraints,
        inc_shortlist: &shortlist,
        inc_shortlist_feats: &shortlist_feats,
        inc_feas: None,
        baseline,
    };
    let slate: Vec<Point> = (0..8)
        .map(|_| Point {
            config: Config::from_id(rng.below(288)),
            s_idx: rng.below(5),
        })
        .collect();

    std::env::set_var("TRIMTUNER_ALPHA", "clone");
    assert_eq!(AlphaMode::from_env(), AlphaMode::Clone);
    // AlphaSlate::new must honor the hatch and reproduce the reference
    // per-candidate path bit for bit
    let hatch = AlphaSlate::new(&ctx).eval_points(&slate);
    std::env::remove_var("TRIMTUNER_ALPHA");
    for (p, b) in slate.iter().zip(&hatch) {
        let a = trimtuner_alpha(&ctx, &encode(p));
        assert_eq!(a.to_bits(), b.to_bits(), "clone hatch diverged");
    }
}
