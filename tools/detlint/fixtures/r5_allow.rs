// R5 allow: the fixed shutdown protocol — close the submit queue and
// release the result receiver *before* joining, so workers blocked in
// `send` unblock on the disconnect and the join terminates.
use std::sync::mpsc::{Receiver, SyncSender};
use std::thread::JoinHandle;

struct Pool {
    submit_tx: Option<SyncSender<u64>>,
    result_rx: Option<Receiver<u64>>,
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    fn close(&mut self) {
        self.submit_tx.take();
        self.result_rx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
