//! Tables I–IV of the paper.

use super::figures::{run_matrix, RunStore};
use super::ExpOptions;
use crate::engine::{EngineConfig, OptimizerKind};
use crate::heuristics::FilterKind;
use crate::models::ModelKind;
use crate::sim::{Dataset, NetKind};
use crate::space::{
    Constraint, BATCH_SIZES, LEARNING_RATES, NVMS, N_CONFIGS, N_POINTS,
    S_VALUES, VM_TYPES,
};
use crate::util::csv::CsvWriter;
use anyhow::Result;

/// Table I: the search space. Mostly a sanity printout of the catalog.
pub fn table1(opts: &ExpOptions) -> Result<()> {
    println!("== Table I: search space ==");
    println!("learning rates: {LEARNING_RATES:?}");
    println!("batch sizes:    {BATCH_SIZES:?}");
    println!("training modes: [sync, async]");
    println!(
        "data-set sizes: {:?} (%)",
        S_VALUES.iter().map(|s| s * 100.0).collect::<Vec<_>>()
    );
    for (vm, nvms) in VM_TYPES.iter().zip(NVMS.iter()) {
        println!(
            "{:<12} {{{} vCPU, {} GB}}  #VMs {:?}  (${}/h)",
            vm.name,
            vm.vcpus,
            vm.ram_gb,
            nvms,
            vm.price_hr()
        );
    }
    println!("=> {N_CONFIGS} configs x {} sizes = {N_POINTS} points", S_VALUES.len());

    let mut w = CsvWriter::create(
        format!("{}/table1.csv", opts.out_dir),
        &["vm_type", "vcpus", "ram_gb", "price_hr", "nvms"],
    )?;
    for (vm, nvms) in VM_TYPES.iter().zip(NVMS.iter()) {
        w.row(&[
            vm.name.to_string(),
            vm.vcpus.to_string(),
            vm.ram_gb.to_string(),
            format!("{}", vm.price_hr()),
            format!("{nvms:?}").replace(',', ";"),
        ])?;
    }
    w.flush()
}

/// Table II: feasible / near-optimal configuration counts per network.
pub fn table2(opts: &ExpOptions) -> Result<()> {
    // paper's measured values for side-by-side comparison
    let paper = [
        (NetKind::Rnn, 178, 61.8, 28, 9.72),
        (NetKind::Mlp, 161, 55.8, 29, 10.07),
        (NetKind::Cnn, 111, 38.5, 39, 13.54),
    ];
    println!("== Table II: feasible configurations (paper vs ours) ==");
    println!(
        "{:<5} {:>14} {:>14} {:>18} {:>18}",
        "net", "feas (paper)", "feas (ours)", "near-opt (paper)", "near-opt (ours)"
    );
    let mut w = CsvWriter::create(
        format!("{}/table2.csv", opts.out_dir),
        &[
            "net", "feasible", "feasible_pct", "near_optimal",
            "near_optimal_pct", "paper_feasible_pct", "paper_near_pct",
        ],
    )?;
    for (net, pf, pfp, pn, pnp) in paper {
        let d = Dataset::generate(net, opts.dataset_seed);
        let caps = [Constraint::cost_max(net.paper_cost_cap())];
        let s = d.feasibility_stats(&caps);
        println!(
            "{:<5} {:>6} ({:4.1}%) {:>6} ({:4.1}%) {:>10} ({:5.2}%) {:>10} ({:5.2}%)",
            net.name(),
            pf,
            pfp,
            s.feasible,
            s.feasible_pct,
            pn,
            pnp,
            s.near_optimal,
            s.near_optimal_pct
        );
        w.row(&[
            net.name().to_string(),
            s.feasible.to_string(),
            format!("{:.2}", s.feasible_pct),
            s.near_optimal.to_string(),
            format!("{:.2}", s.near_optimal_pct),
            format!("{pfp}"),
            format!("{pnp}"),
        ])?;
    }
    w.flush()
}

/// Table III: average wall-clock time to recommend a configuration,
/// averaged over the three networks.
pub fn table3(opts: &ExpOptions) -> Result<()> {
    table3_from(opts, None)
}

pub fn table3_from(opts: &ExpOptions, store: Option<&RunStore>) -> Result<()> {
    let optimizers = [
        OptimizerKind::TrimTuner(ModelKind::Gp),
        OptimizerKind::TrimTuner(ModelKind::Trees),
        OptimizerKind::Fabolas,
        OptimizerKind::Eic,
    ];
    // paper values in minutes (Table III)
    let paper_min = [18.65, 1.36, 13.96, 1.17];

    let local;
    let store = match store {
        Some(s) => s,
        None => {
            let mut o = opts.clone();
            o.seeds = o.seeds.min(3);
            local = run_matrix(&o, &NetKind::ALL, &optimizers)?;
            &local
        }
    };

    println!("== Table III: avg time to recommend a configuration ==");
    println!(
        "{:<14} {:>16} {:>16} {:>10}",
        "optimizer", "paper [min]", "ours [ms]", "ours/DT"
    );
    let mut rows = Vec::new();
    let mut dt_ms = f64::NAN;
    for (i, opt) in optimizers.iter().enumerate() {
        let mut times = Vec::new();
        for net in NetKind::ALL {
            if let Some(runs) = store.get(&(net.name().into(), opt.name())) {
                times.extend(runs.iter().map(|r| r.mean_rec_wall_s()));
            }
        }
        let (mean_s, std_s) = crate::util::stats::mean_std_pop(&times);
        if *opt == OptimizerKind::TrimTuner(ModelKind::Trees) {
            dt_ms = mean_s * 1e3;
        }
        rows.push((opt.name(), paper_min[i], mean_s * 1e3, std_s * 1e3));
    }
    let mut w = CsvWriter::create(
        format!("{}/table3.csv", opts.out_dir),
        &["optimizer", "paper_min", "ours_ms", "ours_std_ms", "ratio_to_dt"],
    )?;
    for (name, paper, ms, std) in rows {
        println!(
            "{:<14} {:>16.2} {:>16.1} {:>10.2}",
            name,
            paper,
            ms,
            ms / dt_ms
        );
        w.row(&[
            name.clone(),
            format!("{paper}"),
            format!("{ms:.2}"),
            format!("{std:.2}"),
            format!("{:.3}", ms / dt_ms),
        ])?;
    }
    w.flush()
}

/// Table IV: recommendation time per filtering heuristic / level (RNN).
pub fn table4(opts: &ExpOptions) -> Result<()> {
    let rows: Vec<(&str, FilterKind, f64)> = vec![
        ("No filter", FilterKind::NoFilter, 1.0),
        ("CEA (1%)", FilterKind::Cea, 0.01),
        ("CEA (10%)", FilterKind::Cea, 0.10),
        ("CEA (20%)", FilterKind::Cea, 0.20),
        ("Direct (10%)", FilterKind::Direct, 0.10),
        ("CMAES (10%)", FilterKind::Cmaes, 0.10),
        ("Random (10%)", FilterKind::RandomFilter, 0.10),
    ];
    // paper values [min] for (GP, DT)
    let paper = [
        (125.76, 3.69),
        (5.94, 1.07),
        (16.85, 1.72),
        (28.65, 2.05),
        (36.18, 2.63),
        (30.87, 2.26),
        (16.53, 1.62),
    ];

    let dataset = Dataset::generate(NetKind::Rnn, opts.dataset_seed);
    let caps = [Constraint::cost_max(NetKind::Rnn.paper_cost_cap())];
    // shorter runs: recommendation latency stabilizes quickly with n
    let iters = opts.max_iters.min(if opts.full { 20 } else { 10 });
    let seeds = opts.seeds.min(if opts.full { 3 } else { 2 });

    println!("== Table IV: recommendation time by heuristic (RNN) ==");
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>12}",
        "heuristic", "GP paper[m]", "GP ours[ms]", "DT paper[m]", "DT ours[ms]"
    );
    let mut w = CsvWriter::create(
        format!("{}/table4.csv", opts.out_dir),
        &[
            "heuristic", "beta", "gp_paper_min", "gp_ours_ms", "dt_paper_min",
            "dt_ours_ms",
        ],
    )?;
    for ((label, filter, beta), (gp_paper, dt_paper)) in
        rows.iter().zip(paper.iter())
    {
        let mut ours = [0.0f64; 2];
        for (k, kind) in [ModelKind::Gp, ModelKind::Trees].iter().enumerate()
        {
            let mut times = Vec::new();
            for seed in 0..seeds {
                let mut cfg = EngineConfig::paper_default(
                    OptimizerKind::TrimTuner(*kind),
                    seed as u64,
                );
                cfg.filter = *filter;
                cfg.beta = *beta;
                cfg.max_iters = iters;
                let run = crate::engine::run(&dataset, &caps, &cfg);
                times.push(run.mean_rec_wall_s());
            }
            ours[k] = crate::util::stats::mean(&times) * 1e3;
        }
        println!(
            "{:<14} {:>12.2} {:>12.1} {:>12.2} {:>12.1}",
            label, gp_paper, ours[0], dt_paper, ours[1]
        );
        w.row(&[
            label.to_string(),
            format!("{beta}"),
            format!("{gp_paper}"),
            format!("{:.2}", ours[0]),
            format!("{dt_paper}"),
            format!("{:.2}", ours[1]),
        ])?;
    }
    w.flush()
}
