//! Table I: parameter values and the VM catalog.

/// AWS t2.* on-demand types used in the paper (us-east-1, mid-2020 pricing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VmType {
    pub name: &'static str,
    pub vcpus: u32,
    pub ram_gb: u32,
    /// USD per VM-hour (on-demand)
    pub price_hr_milli: u32, // milli-USD to keep the type Copy+Eq
}

impl VmType {
    pub fn price_hr(&self) -> f64 {
        self.price_hr_milli as f64 / 1000.0
    }
}

/// The four t2 types of Table I with their allowed fleet sizes.
pub const VM_TYPES: [VmType; 4] = [
    VmType { name: "t2.small", vcpus: 1, ram_gb: 2, price_hr_milli: 23 },
    VmType { name: "t2.medium", vcpus: 2, ram_gb: 4, price_hr_milli: 46 },
    VmType { name: "t2.xlarge", vcpus: 4, ram_gb: 16, price_hr_milli: 186 },
    VmType { name: "t2.2xlarge", vcpus: 8, ram_gb: 32, price_hr_milli: 371 },
];

/// Allowed #VMs per VM type (row-aligned with [`VM_TYPES`]). Each row keeps
/// the total vCPU budget in {8,16,32,48,64,80} like the paper.
pub const NVMS: [[u32; 6]; 4] = [
    [8, 16, 32, 48, 64, 80],
    [4, 8, 16, 24, 32, 40],
    [2, 4, 8, 12, 16, 20],
    [1, 2, 4, 6, 8, 10],
];

pub const LEARNING_RATES: [f64; 3] = [1e-3, 1e-4, 1e-5];
pub const BATCH_SIZES: [u32; 2] = [16, 256];
pub const SYNC_MODES: [&str; 2] = ["sync", "async"];

/// Sub-sampling rates (fraction of the full data-set). The paper's MNIST
/// levels: 1/60 (1000 samples), 1/10, 1/4, 1/2 for bootstrap + 1 (full).
pub const S_VALUES: [f64; 5] = [1.0 / 60.0, 0.10, 0.25, 0.50, 1.0];
/// Indices of the sub-sampling levels used in the initialization phase.
pub const S_INIT: [usize; 4] = [0, 1, 2, 3];
/// Full MNIST training-set size.
pub const FULL_DATASET: u32 = 60_000;

pub const N_CONFIGS: usize =
    LEARNING_RATES.len() * BATCH_SIZES.len() * SYNC_MODES.len() * 4 * 6; // 288
pub const N_POINTS: usize = N_CONFIGS * S_VALUES.len(); // 1440

/// One cloud + hyper-parameter configuration (288 total).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Config {
    pub lr_idx: usize,    // 0..3
    pub batch_idx: usize, // 0..2
    pub sync: bool,       // true == synchronous training
    pub vm_idx: usize,    // 0..4
    pub nvm_idx: usize,   // 0..6
}

impl Config {
    pub fn learning_rate(&self) -> f64 {
        LEARNING_RATES[self.lr_idx]
    }
    pub fn batch_size(&self) -> u32 {
        BATCH_SIZES[self.batch_idx]
    }
    pub fn vm(&self) -> VmType {
        VM_TYPES[self.vm_idx]
    }
    pub fn nvms(&self) -> u32 {
        NVMS[self.vm_idx][self.nvm_idx]
    }
    pub fn total_vcpus(&self) -> u32 {
        self.nvms() * self.vm().vcpus
    }
    /// Fleet cost per hour in USD.
    pub fn fleet_price_hr(&self) -> f64 {
        self.nvms() as f64 * self.vm().price_hr()
    }

    /// Dense index in 0..288 (row-major over the Table-I axes).
    pub fn id(&self) -> usize {
        (((self.lr_idx * BATCH_SIZES.len() + self.batch_idx) * 2
            + self.sync as usize)
            * VM_TYPES.len()
            + self.vm_idx)
            * 6
            + self.nvm_idx
    }

    pub fn from_id(id: usize) -> Config {
        assert!(id < N_CONFIGS);
        let nvm_idx = id % 6;
        let rest = id / 6;
        let vm_idx = rest % VM_TYPES.len();
        let rest = rest / VM_TYPES.len();
        let sync = rest % 2 == 1;
        let rest = rest / 2;
        let batch_idx = rest % BATCH_SIZES.len();
        let lr_idx = rest / BATCH_SIZES.len();
        Config { lr_idx, batch_idx, sync, vm_idx, nvm_idx }
    }

    pub fn describe(&self) -> String {
        format!(
            "{}x{} lr={:.0e} batch={} {}",
            self.nvms(),
            self.vm().name,
            self.learning_rate(),
            self.batch_size(),
            if self.sync { "sync" } else { "async" },
        )
    }
}

/// A (config, sub-sampling level) pair — the unit the optimizer tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Point {
    pub config: Config,
    pub s_idx: usize, // 0..5 into S_VALUES
}

impl Point {
    pub fn s(&self) -> f64 {
        S_VALUES[self.s_idx]
    }
    pub fn dataset_size(&self) -> u32 {
        (self.s() * FULL_DATASET as f64).round() as u32
    }
    pub fn id(&self) -> usize {
        self.config.id() * S_VALUES.len() + self.s_idx
    }
    pub fn from_id(id: usize) -> Point {
        assert!(id < N_POINTS);
        Point {
            config: Config::from_id(id / S_VALUES.len()),
            s_idx: id % S_VALUES.len(),
        }
    }
    pub fn is_full(&self) -> bool {
        self.s_idx == S_VALUES.len() - 1
    }
}

/// Iterate all 288 configs.
pub fn all_configs() -> impl Iterator<Item = Config> {
    (0..N_CONFIGS).map(Config::from_id)
}

/// Iterate all 1440 (config, s) points.
pub fn all_points() -> impl Iterator<Item = Point> {
    (0..N_POINTS).map(Point::from_id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn catalog_sizes_match_paper() {
        assert_eq!(N_CONFIGS, 288);
        assert_eq!(N_POINTS, 1440);
        assert_eq!(all_configs().count(), 288);
        assert_eq!(all_points().count(), 1440);
    }

    #[test]
    fn config_id_round_trips() {
        let ids: HashSet<usize> = all_configs().map(|c| c.id()).collect();
        assert_eq!(ids.len(), N_CONFIGS);
        for id in 0..N_CONFIGS {
            assert_eq!(Config::from_id(id).id(), id);
        }
        for id in 0..N_POINTS {
            assert_eq!(Point::from_id(id).id(), id);
        }
    }

    #[test]
    fn vcpu_budget_rows_consistent() {
        // Each nvm_idx column scales total vCPUs identically across types.
        for col in 0..6 {
            let totals: Vec<u32> = (0..4)
                .map(|row| NVMS[row][col] * VM_TYPES[row].vcpus)
                .collect();
            assert!(totals.windows(2).all(|w| w[0] == w[1]), "{totals:?}");
        }
    }

    #[test]
    fn s_values_sorted_and_full_last() {
        assert!(S_VALUES.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(S_VALUES[4], 1.0);
        let p = Point { config: Config::from_id(0), s_idx: 4 };
        assert!(p.is_full());
        assert_eq!(p.dataset_size(), FULL_DATASET);
        let p0 = Point { config: Config::from_id(0), s_idx: 0 };
        assert_eq!(p0.dataset_size(), 1000);
    }

    #[test]
    fn fleet_price_positive_and_monotone_in_nvms() {
        for c in all_configs() {
            assert!(c.fleet_price_hr() > 0.0);
        }
        for vm_idx in 0..4 {
            let mut last = 0.0;
            for nvm_idx in 0..6 {
                let c = Config { lr_idx: 0, batch_idx: 0, sync: true, vm_idx, nvm_idx };
                assert!(c.fleet_price_hr() > last);
                last = c.fleet_price_hr();
            }
        }
    }
}
