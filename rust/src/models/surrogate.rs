//! The surrogate-model abstraction shared by GP and decision-tree variants.

use crate::linalg::{Cholesky, Mat};
use crate::space::D_IN;
use crate::util::Rng;

/// A feature vector (6 normalized config features + sub-sampling rate).
pub type Feat = [f64; D_IN];

/// Which surrogate family an optimizer uses (paper: "TrimTuner (GPs)" vs
/// "TrimTuner (DTs)").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Gp,
    Trees,
}

impl ModelKind {
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Gp => "gp",
            ModelKind::Trees => "dt",
        }
    }
}

/// Options controlling a (re)fit.
#[derive(Debug, Clone, Copy)]
pub struct FitOptions {
    /// re-optimize hyper-parameters (GP: MLL Nelder–Mead; trees: n/a)
    pub hyperopt: bool,
    /// random restarts for the hyper-parameter search
    pub restarts: usize,
}

impl Default for FitOptions {
    fn default() -> Self {
        FitOptions { hyperopt: true, restarts: 1 }
    }
}

/// One mixture component of a joint posterior.
pub struct PostComp {
    pub mean: Vec<f64>,
    cov_l: Option<Cholesky>,
    diag_std: Option<Vec<f64>>,
}

/// Joint posterior over a set of points, used for Entropy-Search p_opt
/// Monte-Carlo. GPs carry the full covariance Cholesky factor; tree
/// ensembles an independent per-point std (their ensemble spread carries no
/// cross-covariance information). Hyper-parameter-marginalized GPs
/// (FABOLAS-style) carry one component per hyper-parameter sample;
/// successive draws rotate across components (a draw from the mixture).
pub struct Posterior {
    comps: Vec<PostComp>,
    /// round-robin component cursor for mixture sampling
    cursor: std::cell::Cell<usize>,
    /// mixture mean (averaged across components)
    pub mean: Vec<f64>,
}

impl Posterior {
    fn from_comps(comps: Vec<PostComp>) -> Posterior {
        assert!(!comps.is_empty());
        let n = comps[0].mean.len();
        let mut mean = vec![0.0; n];
        for c in &comps {
            for (m, v) in mean.iter_mut().zip(&c.mean) {
                *m += v / comps.len() as f64;
            }
        }
        Posterior { comps, cursor: std::cell::Cell::new(0), mean }
    }

    pub fn joint(mean: Vec<f64>, cov_l: Cholesky) -> Posterior {
        Posterior::from_comps(vec![PostComp {
            mean,
            cov_l: Some(cov_l),
            diag_std: None,
        }])
    }

    pub fn diagonal(mean: Vec<f64>, std: Vec<f64>) -> Posterior {
        Posterior::from_comps(vec![PostComp {
            mean,
            cov_l: None,
            diag_std: Some(std),
        }])
    }

    pub fn mixture(comps: Vec<(Vec<f64>, Option<Cholesky>, Option<Vec<f64>>)>) -> Posterior {
        Posterior::from_comps(
            comps
                .into_iter()
                .map(|(mean, cov_l, diag_std)| PostComp { mean, cov_l, diag_std })
                .collect(),
        )
    }

    pub fn n_components(&self) -> usize {
        self.comps.len()
    }

    pub fn len(&self) -> usize {
        self.mean.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mean.is_empty()
    }

    /// Draw one sample of the joint function values given pre-drawn
    /// standard normals `z` (common random numbers let the acquisition
    /// function compare candidates without MC jitter; DESIGN.md §6).
    /// Successive calls rotate round-robin over mixture components.
    pub fn sample_with(&self, z: &[f64], out: &mut Vec<f64>) {
        let k = self.cursor.get();
        self.cursor.set((k + 1) % self.comps.len());
        self.sample_component_with(k, z, out);
    }

    /// Sample a specific mixture component.
    pub fn sample_component_with(&self, k: usize, z: &[f64], out: &mut Vec<f64>) {
        let comp = &self.comps[k % self.comps.len()];
        let n = comp.mean.len();
        assert_eq!(z.len(), n);
        out.clear();
        if let Some(l) = &comp.cov_l {
            // f = mean + L z
            let lm: &Mat = l.l();
            for i in 0..n {
                let row = lm.row(i);
                let mut acc = comp.mean[i];
                for j in 0..=i {
                    acc += row[j] * z[j];
                }
                out.push(acc);
            }
        } else {
            let std = comp.diag_std.as_ref().expect("posterior without cov");
            for i in 0..n {
                out.push(comp.mean[i] + std[i] * z[i]);
            }
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> Vec<f64> {
        let z: Vec<f64> = (0..self.len()).map(|_| rng.normal()).collect();
        let mut out = Vec::with_capacity(self.len());
        self.sample_with(&z, &mut out);
        out
    }
}

/// One candidate's conditioned view of a fantasy query grid: the posterior
/// the surrogate *would* have after observing `(x, ŷ(x))`, evaluated on the
/// fixed grid its [`FantasySurface`] was built over.
pub struct FantasyView {
    /// Conditioned mixture (mean, std) on every grid point — matches
    /// `condition(x, ŷ).predict_many(grid)`.
    pub grid: Vec<(f64, f64)>,
    /// Conditioned joint posterior over the grid's joint prefix — matches
    /// `condition(x, ŷ).posterior(&grid[..m_joint])`. `None` when the
    /// surface was built with `m_joint == 0`.
    pub joint: Option<Posterior>,
}

/// Reusable per-worker scratch for the slate sweep's conditioned views —
/// the hot per-candidate loops borrow these buffers instead of allocating
/// fresh vectors per view (each buffer is cleared/overwritten on use, so a
/// dirty scratch can never leak state between candidates).
#[derive(Default)]
pub struct FantasyScratch {
    /// posterior cross-covariance buffer (candidate → grid)
    pub cross: Vec<f64>,
    /// rank-one direction buffer for the joint-factor downdate
    pub rank1: Vec<f64>,
    /// hyperbolic-rotation working vector for `Cholesky::downdate_into`
    pub sweep: Vec<f64>,
    /// per-tree slate accumulators (trees incremental conditioning)
    pub acc: Vec<f64>,
    pub acc2: Vec<f64>,
}

impl FantasyScratch {
    pub fn new() -> FantasyScratch {
        FantasyScratch::default()
    }
}

/// A fantasy surface primed for one specific candidate slate: every
/// per-candidate quantity that can be batched across the slate (GP: the
/// cross-kernel solves `w = L⁻¹k(X, x_c)` collected into one multi-RHS
/// triangular solve per hyper-sample, plus the simulated outcomes ŷ(x_c);
/// trees: one tree-major ŷ sweep) is computed once at
/// [`FantasySurface::prime`] time, so `view_at(c)` pays only the
/// dot-product sweep of candidate `c`.
pub trait PrimedSlate: Send + Sync {
    /// The conditioned view of slate candidate `i` — identical (bit for
    /// bit) to `view(&slate[i])` on the surface that primed this slate.
    fn view_at(&self, i: usize, scratch: &mut FantasyScratch) -> FantasyView;
}

/// Fallback primer for surfaces without a batched implementation: defers
/// every candidate to [`FantasySurface::view`].
struct MapPrimed<'s, S: ?Sized> {
    surf: &'s S,
    xs: &'s [Feat],
}

impl<S: FantasySurface + ?Sized> PrimedSlate for MapPrimed<'_, S> {
    fn view_at(&self, i: usize, _scratch: &mut FantasyScratch) -> FantasyView {
        self.surf.view(&self.xs[i])
    }
}

/// Per-iteration fantasy-conditioning surface over a fixed query grid.
///
/// Built once per acquisition round via [`Surrogate::fantasy_surface`];
/// every [`FantasySurface::view`] call then yields the grid under the
/// surrogate conditioned on one simulated observation `(x, ŷ(x))` — for
/// GPs via closed-form rank-one posterior algebra (no surrogate clone, no
/// Cholesky re-factorization), for tree ensembles via the incremental
/// leaf-statistics path over one cached conditioned structure.
///
/// `Send + Sync` so the slate evaluator can shard candidate views across
/// `std::thread::scope` workers.
pub trait FantasySurface: Send + Sync {
    /// The conditioned view for one candidate. The simulated outcome is
    /// the surrogate's own predictive mean at `x` — the single-root
    /// Gauss–Hermite collapse `Models::condition` uses.
    fn view(&self, x: &Feat) -> FantasyView;

    /// Prime the surface for a whole candidate slate (see [`PrimedSlate`]).
    /// The default defers to per-candidate [`FantasySurface::view`] calls;
    /// the native models override it with genuinely batched precomputation
    /// that stays bit-identical to the per-candidate path.
    fn prime<'s>(&'s self, xs: &'s [Feat]) -> Box<dyn PrimedSlate + 's> {
        Box::new(MapPrimed { surf: self, xs })
    }
}

/// Reference fantasy surface for surrogates without a specialized
/// implementation: clone-and-condition per candidate — exactly the
/// baseline the rank-one paths are verified against.
struct CloneFantasy {
    base: Box<dyn Surrogate>,
    grid: Vec<Feat>,
    m_joint: usize,
}

impl FantasySurface for CloneFantasy {
    fn view(&self, x: &Feat) -> FantasyView {
        let (y, _) = self.base.predict(x);
        let cond = self.base.condition(x, y);
        let grid = cond.predict_many(&self.grid);
        let joint = (self.m_joint > 0)
            .then(|| cond.posterior(&self.grid[..self.m_joint]));
        FantasyView { grid, joint }
    }
}

/// A Bayesian surrogate over the (config, s) feature space.
///
/// The acquisition hot path relies on [`Surrogate::condition`]: a cheap
/// clone extended with one hypothetical observation while hyper-parameters
/// stay frozen (GP: O(n²) Cholesky extension; trees: a fresh seeded
/// bootstrap whose structure is built from the existing observations, with
/// the new observation folded into the leaf statistics it lands in).
///
/// `Send + Sync` because the slate evaluator shares fitted surrogates
/// (read-only) across `std::thread::scope` workers.
pub trait Surrogate: Send + Sync {
    /// Fit from scratch on (xs, ys).
    fn fit(&mut self, xs: &[Feat], ys: &[f64], opts: FitOptions);

    /// Predictive mean and standard deviation at one point.
    fn predict(&self, x: &Feat) -> (f64, f64);

    /// Batch prediction over a whole candidate slate. The default maps
    /// [`Surrogate::predict`]; both native models override it with a
    /// genuinely batched pass (GP: one multi-RHS triangular solve; trees:
    /// one cache-friendly tree-major traversal) that is bit-identical to
    /// the scalar path.
    fn predict_many(&self, xs: &[Feat]) -> Vec<(f64, f64)> {
        xs.iter().map(|x| self.predict(x)).collect()
    }

    /// Joint posterior over `xs` (for p_opt sampling).
    fn posterior(&self, xs: &[Feat]) -> Posterior;

    /// Clone extended with one observation, hyper-parameters frozen.
    fn condition(&self, x: &Feat, y: f64) -> Box<dyn Surrogate>;

    /// Build a fantasy surface over a fixed query grid: shared
    /// per-iteration precomputation, then one cheap conditioned view per
    /// candidate. Views carry a joint conditioned posterior over the first
    /// `m_joint` grid points (for p_opt sampling) and conditioned
    /// (mean, std) everywhere. The default clones + conditions per view;
    /// the native models override it (GP: rank-one posterior algebra over
    /// precomputed cross-solves; trees: incremental leaf-statistics
    /// conditioning over one cached fused-grid structure).
    fn fantasy_surface(
        &self,
        grid: &[Feat],
        m_joint: usize,
    ) -> Box<dyn FantasySurface> {
        assert!(m_joint <= grid.len());
        Box::new(CloneFantasy {
            base: self.clone_box(),
            grid: grid.to_vec(),
            m_joint,
        })
    }

    /// Number of observations currently fitted.
    fn n_obs(&self) -> usize;

    fn clone_box(&self) -> Box<dyn Surrogate>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn diagonal_posterior_sampling_moments() {
        let p = Posterior::diagonal(vec![1.0, -2.0], vec![0.5, 2.0]);
        let mut rng = Rng::new(3);
        let n = 20_000;
        let (mut m0, mut m1, mut v0, mut v1) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let s = p.sample(&mut rng);
            m0 += s[0];
            m1 += s[1];
            v0 += (s[0] - 1.0) * (s[0] - 1.0);
            v1 += (s[1] + 2.0) * (s[1] + 2.0);
        }
        let n = n as f64;
        assert!((m0 / n - 1.0).abs() < 0.02);
        assert!((m1 / n + 2.0).abs() < 0.05);
        assert!((v0 / n - 0.25).abs() < 0.02);
        assert!((v1 / n - 4.0).abs() < 0.15);
    }

    #[test]
    fn joint_posterior_respects_covariance() {
        // cov = [[1, 0.9], [0.9, 1]] -> samples strongly correlated
        let k = Mat::from_rows(&[vec![1.0, 0.9], vec![0.9, 1.0]]);
        let l = crate::linalg::Cholesky::factor(&k).unwrap();
        let p = Posterior::joint(vec![0.0, 0.0], l);
        let mut rng = Rng::new(4);
        let mut corr = 0.0;
        let n = 20_000;
        for _ in 0..n {
            let s = p.sample(&mut rng);
            corr += s[0] * s[1];
        }
        assert!((corr / n as f64 - 0.9).abs() < 0.05);
    }
}
