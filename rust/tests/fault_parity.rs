//! Fault-injection contracts: a zero-valued fault stack is an exact
//! no-op, fault traces are deterministic in the worker count, and a
//! campaign under deterministic kills survives — abandoned probes charge
//! their partial cost, produce no phantom observations, and never feed
//! the NoImprovement stop condition.

use trimtuner::coordinator::{
    job_ids, EventKind, FaultSpec, Interrupted, Job, JobLauncher, JobResult,
    SimLauncher,
};
use trimtuner::engine::{
    self, EngineConfig, EvalBackend, LiveEval, OptimizerKind, RetryPolicy,
    RunResult, StopCondition,
};
use trimtuner::models::ModelKind;
use trimtuner::sim::{Dataset, NetKind};
use trimtuner::space::Constraint;

fn caps(net: NetKind) -> Vec<Constraint> {
    vec![Constraint::cost_max(net.paper_cost_cap())]
}

/// Paper defaults shrunk like `live_parity`'s so the runs stay fast.
fn small_cfg(optimizer: OptimizerKind, seed: u64, iters: usize) -> EngineConfig {
    let mut cfg = EngineConfig::paper_default(optimizer, seed);
    cfg.max_iters = iters;
    cfg.n_rep = 10;
    cfg.n_popt_samples = 40;
    cfg.gp_hyper_samples = cfg.gp_hyper_samples.min(2);
    cfg
}

/// Run live with an arbitrary launcher stack; returns the result plus the
/// event log's `ProbeAbandoned` count (read before shutdown).
fn live_run(
    launcher: Box<dyn JobLauncher>,
    workers: usize,
    retry: RetryPolicy,
    eval: &Dataset,
    constraints: &[Constraint],
    cfg: &EngineConfig,
) -> (RunResult, usize) {
    let mut backend = EvalBackend::Live(
        LiveEval::new(launcher, workers)
            .with_eval(eval)
            .with_retry(retry, cfg.seed ^ 0xB0FF),
    );
    let run = engine::run_backend(&mut backend, constraints, cfg)
        .expect("live run failed");
    let abandoned_events = backend
        .event_log()
        .map(|log| log.count(|k| matches!(k, EventKind::ProbeAbandoned { .. })))
        .unwrap_or(0);
    backend.shutdown();
    (run, abandoned_events)
}

fn assert_same_trajectory(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.tested.id(), rb.tested.id(), "{label}: tested point");
        assert_eq!(
            ra.outcome.acc.to_bits(),
            rb.outcome.acc.to_bits(),
            "{label}: observed accuracy"
        );
        assert_eq!(
            ra.explore_cost.to_bits(),
            rb.explore_cost.to_bits(),
            "{label}: charged cost"
        );
        assert_eq!(
            ra.cum_cost.to_bits(),
            rb.cum_cost.to_bits(),
            "{label}: cumulative cost"
        );
        assert_eq!(
            ra.duration_s.to_bits(),
            rb.duration_s.to_bits(),
            "{label}: measured duration"
        );
        assert_eq!(ra.incumbent.id(), rb.incumbent.id(), "{label}: incumbent");
    }
}

/// ISSUE acceptance: the full fault stack configured at zero rates is
/// bit-exactly the bare launcher — every decorator is an exact
/// pass-through, the engine's retry plumbing charges exactly +0.0.
#[test]
fn zero_fault_stack_is_bit_exact_with_the_bare_launcher() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    let zero = FaultSpec {
        spot: Some(0.0),
        straggle: Some(0.0),
        flaky: Some(0.0),
        // a deadline no simulated run approaches is the same as none
        timeout: Some(1e12),
        fallback: false,
        market: None,
    };
    assert!(!zero.is_empty(), "explicit zeros still build the stack");
    for (optimizer, iters) in [
        (OptimizerKind::TrimTuner(ModelKind::Gp), 3),
        (OptimizerKind::TrimTuner(ModelKind::Trees), 6),
    ] {
        let cfg = small_cfg(optimizer, 5, iters);
        let mk_base = || Box::new(SimLauncher::new(net, 33)) as Box<dyn JobLauncher>;
        let (bare, _) = live_run(
            mk_base(),
            2,
            RetryPolicy::default(),
            &truth,
            &constraints,
            &cfg,
        );
        let (stacked, _) = live_run(
            zero.wrap(mk_base(), 0xFA17),
            2,
            RetryPolicy::default(),
            &truth,
            &constraints,
            &cfg,
        );
        assert_same_trajectory(&bare, &stacked, &optimizer.name());
        assert_eq!(stacked.faults, bare.faults, "no faults at rate 0");
        assert_eq!(stacked.faults.n_failures, 0);
    }
}

/// Fault decisions are keyed by (seed, job id) and job ids by submission
/// order, so the whole fault trace — failures, abandonments, waste totals
/// to the bit — must be identical at 1 and 4 workers.
#[test]
fn fault_trace_is_deterministic_across_worker_counts() {
    let net = NetKind::Mlp;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    let spec = FaultSpec::parse("spot:0.4,straggle:2.0,flaky:0.3").unwrap();
    let mut cfg = small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 9, 8);
    cfg.batch_size = 2;
    let mk = |workers| {
        live_run(
            spec.wrap(Box::new(SimLauncher::new(net, 33)), 0xFA17),
            workers,
            RetryPolicy::default(),
            &truth,
            &constraints,
            &cfg,
        )
    };
    let (one, one_abandoned) = mk(1);
    let (four, four_abandoned) = mk(4);
    assert_same_trajectory(&one, &four, "faulty 1 vs 4 workers");
    assert_eq!(one.faults.n_failures, four.faults.n_failures);
    assert_eq!(one.faults.n_abandoned, four.faults.n_abandoned);
    assert_eq!(
        one.faults.wasted_cost.to_bits(),
        four.faults.wasted_cost.to_bits(),
        "waste totals must match bitwise"
    );
    assert_eq!(
        one.faults.wasted_time.to_bits(),
        four.faults.wasted_time.to_bits()
    );
    assert_eq!(one_abandoned, four_abandoned);
    assert!(
        one.faults.n_failures > 0,
        "a 40% preemption + 30% flaky cocktail over 9+ jobs must fault"
    );
}

/// Kills every attempt (primary and retries) of the probes whose *primary*
/// id is listed — a deterministic preemption charging half the real cost
/// per dead attempt, guaranteed to exhaust any retry budget.
struct KillListLauncher {
    inner: SimLauncher,
    kill: fn(u64) -> bool,
}

impl JobLauncher for KillListLauncher {
    fn launch(&self, job: &Job) -> anyhow::Result<JobResult> {
        let r = self.inner.launch(job)?;
        if (self.kill)(job_ids::original(job.id)) {
            return Err(anyhow::Error::new(Interrupted {
                partial_cost: r.charged_cost * 0.5,
                partial_duration_s: r.duration_s * 0.5,
            }));
        }
        Ok(r)
    }
}

/// ISSUE acceptance: a campaign whose probes die deterministically keeps
/// going — the abandoned probes are charged their partial cost into the
/// cumulative totals, logged as `ProbeAbandoned`, and produce no records;
/// the launch budget is fully consumed either way.
#[test]
fn campaign_survives_kills_with_partial_charges_and_no_phantom_records() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let cfg = small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 3, 6);
    // job ids: 0 = the init snapshot, 1..=6 the six main-loop primaries.
    // Kill 2 and 5 — mid-run, so a later observed round folds their waste
    // into its cumulative totals.
    let launcher = KillListLauncher {
        inner: SimLauncher::noiseless(net),
        kill: |id| id == 2 || id == 5,
    };
    let retry = RetryPolicy { max_retries: 1, ..RetryPolicy::default() };
    let (run, abandoned_events) =
        live_run(Box::new(launcher), 2, retry, &truth, &caps(net), &cfg);
    assert_eq!(run.faults.n_abandoned, 2);
    assert_eq!(run.faults.n_failures, 4, "2 probes x (1 primary + 1 retry)");
    assert!(run.faults.wasted_cost > 0.0);
    assert_eq!(abandoned_events, 2);
    // 4 init records + (6 launched - 2 abandoned) main records, no holes
    let n_init = run.records.iter().filter(|r| r.is_init).count();
    assert_eq!(n_init, 4);
    assert_eq!(run.records.len(), n_init + 4);
    // main-loop observation indices stay contiguous despite the holes
    for (i, r) in
        run.records.iter().filter(|r| !r.is_init).enumerate()
    {
        assert_eq!(r.iter, i, "observation indices stay contiguous");
    }
    // the waste is charged: cumulative cost ends above the sum of the
    // observed probes' own charges
    let observed_sum: f64 =
        run.records.iter().map(|r| r.explore_cost).sum();
    assert!(
        run.total_cost() > observed_sum,
        "cum {} must exceed observed {}",
        run.total_cost(),
        observed_sum
    );
}

/// Satellite: rounds that observed nothing must not feed
/// `StopCondition::NoImprovement`. With an unmeetable `min_delta`, the
/// condition would stop as soon as the window overflows — so after the
/// first two observed rounds, a correct engine never stops on the six
/// abandoned-only rounds that follow, and the full launch budget runs out.
#[test]
fn abandoned_only_rounds_are_not_no_improvement_evidence() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let mut cfg = small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 3, 8);
    cfg.stop = StopCondition::NoImprovement { window: 2, min_delta: 1.0 };
    // id 0 = init; main ids 1 and 2 observe, everything later is killed
    let launcher = KillListLauncher {
        inner: SimLauncher::noiseless(net),
        kill: |id| id >= 3,
    };
    let retry = RetryPolicy { max_retries: 0, ..RetryPolicy::default() };
    let (run, _) =
        live_run(Box::new(launcher), 2, retry, &truth, &caps(net), &cfg);
    let n_main = run.records.iter().filter(|r| !r.is_init).count();
    assert_eq!(n_main, 2, "only the two pre-kill rounds observe");
    assert_eq!(
        run.faults.n_abandoned, 6,
        "the remaining budget was launched and abandoned, not stopped on"
    );
}

/// Backoff sleeps shift wall time only: a run with real (tiny) backoff
/// delays is bit-identical to one without.
#[test]
fn backoff_sleep_does_not_change_the_trajectory() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    let spec = FaultSpec::parse("flaky:0.5").unwrap();
    let cfg = small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 11, 5);
    let mk = |retry: RetryPolicy| {
        live_run(
            spec.wrap(Box::new(SimLauncher::new(net, 33)), 0xFA17),
            2,
            retry,
            &truth,
            &constraints,
            &cfg,
        )
    };
    let (no_sleep, _) = mk(RetryPolicy::default());
    let (slept, _) = mk(RetryPolicy {
        backoff_base_s: 0.002,
        backoff_max_s: 0.01,
        ..RetryPolicy::default()
    });
    assert_same_trajectory(&no_sleep, &slept, "backoff sleep");
    assert_eq!(no_sleep.faults, slept.faults);
}
